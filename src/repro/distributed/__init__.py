"""Distribution: mesh sharding specs, ISL-aware compression."""
from .compression import (decompress_tree, ef_compress_tree, ef_init,
                          int8_compress, int8_decompress, topk_compress,
                          topk_decompress, tree_bytes_f32)
from .sharding import (batch_axes, batch_specs, cache_specs, opt_state_specs,
                       param_specs)
