"""Distribution: mesh sharding specs, ISL-aware compression."""
from .compression import (WireFormat, WireLeaf, decompress_tree,
                          ef_compress_tree, ef_init, ef_wire_roundtrip,
                          int8_compress, int8_decompress, topk_compress,
                          topk_decompress, tree_bytes_f32, wire_format_for,
                          wire_leaf_bytes, wire_tree_bytes)
from .sharding import (batch_axes, batch_specs, cache_specs, opt_state_specs,
                       param_specs)
