"""Partition-spec builders: how every architecture shards on the production
mesh ("pod", "data", "model").

Strategy (DESIGN.md §5):
  - "model": tensor parallel — attention heads / FFN hidden / experts /
    vocab. Megatron pairing: column-parallel in-projections, row-parallel
    out-projections (one all-reduce per block).
  - "data" (+ "pod"): batch data-parallelism; with fsdp=True the weights'
    *other* dimension additionally shards over "data" (ZeRO-3 — parameters
    are all-gathered per layer inside the scan, gradients reduce-scattered),
    which is what makes the 30B+ archs fit 16 GB chips with fp32 optimizer
    state.
  - "pod": pure DP over satellites. Sync baseline all-reduces gradients over
    it; DiLoCo mode removes that traffic (train/diloco.py).
  - Batch axis of activations shards over ("pod", "data"); the model axis of
    activations stays unsharded except the vocab dim of logits.

Small archs (xlstm) leave recurrent/head-structured weights replicated where
head counts don't divide the model axis — noted per-family below.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# NOTE: model config classes are imported lazily inside the dispatchers —
# models import repro.distributed.hints, so a top-level import here would
# be circular.


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


# --------------------------------------------------------------------------
# spec -> NamedSharding plumbing (shared by launch/dryrun.py, launch/train.py
# and train/loop.py / train/diloco.py)
# --------------------------------------------------------------------------
def _is_spec_leaf(x):
    return x is None or isinstance(x, P)


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def sanitize_specs(spec_tree, sds_tree, mesh):
    """Drop sharding on axes whose size doesn't divide (e.g. batch=1 cells,
    4-head archs on a 16-way model axis, 2 DiLoCo pods on a 1-pod mesh)."""
    sizes = _axis_sizes(mesh)

    def fix(spec, sds):
        if spec is None or not isinstance(spec, P):
            spec = P()
        parts = list(spec) + [None] * (len(sds.shape) - len(spec))
        out = []
        for dim, ax in zip(sds.shape, parts):
            if ax is None:
                out.append(None)
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            if any(a not in sizes for a in axs):
                out.append(None)
                continue
            n = math.prod(sizes[a] for a in axs)
            out.append(ax if dim % n == 0 else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, sds_tree, is_leaf=_is_spec_leaf)


def shardings_for(spec_tree, sds_tree, mesh):
    """PartitionSpec tree -> NamedSharding tree, sanitized against the mesh
    axis sizes and the concrete array shapes in `sds_tree`."""
    specs = sanitize_specs(spec_tree, sds_tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=_is_spec_leaf)


def prepend_axis(spec_tree, axis=None):
    """Prefix every spec with one leading dim (DiLoCo pod-replica axis,
    fused-step block axis, ...). axis=None keeps the new dim unsharded."""
    return jax.tree.map(lambda s: P(*((axis,) + tuple(s or P()))),
                        spec_tree, is_leaf=_is_spec_leaf)


def _transformer_specs(cfg: TransformerConfig, fsdp: bool, dp):
    d = dp if fsdp else None   # the FSDP shard axis
    L = lambda *rest: P(*((None,) + rest))
    layers = {
        "attn_norm": L(None),
        "wq": L(d, "model"),
        "wk": L(d, "model"),
        "wv": L(d, "model"),
        "wo": L("model", d),
    }
    if cfg.norm == "layernorm":
        layers["attn_norm_bias"] = L(None)
    if cfg.qkv_bias:
        layers["bq"] = L("model")
        layers["bk"] = L("model")
        layers["bv"] = L("model")
    if not cfg.parallel_block:
        layers["mlp_norm"] = L(None)
        if cfg.norm == "layernorm":
            layers["mlp_norm_bias"] = L(None)
    if cfg.is_moe:
        layers["router"] = L(d, None)
        layers["moe_wi_gate"] = L("model", d, None)   # experts on "model"
        layers["moe_wi_up"] = L("model", d, None)
        layers["moe_wo"] = L("model", None, d)
    elif cfg.mlp_act == "gelu":
        layers["wi"] = L(d, "model")
        layers["bi"] = L("model")
        layers["wo_mlp"] = L("model", d)
        layers["bo"] = L(None)
    else:
        layers["wi_gate"] = L(d, "model")
        layers["wi_up"] = L(d, "model")
        layers["wo_mlp"] = L("model", d)

    embed = (P(None, "model", d) if cfg.n_codebooks > 1
             else P("model", d))
    specs = {"embed": embed, "layers": layers, "final_norm": P(None)}
    if cfg.norm == "layernorm":
        specs["final_norm_bias"] = P(None)
    if not cfg.tie_embeddings:
        specs["lm_head"] = (P(None, d, "model") if cfg.n_codebooks > 1
                            else P(d, "model"))
    return specs


def _xlstm_specs(cfg: XLSTMConfig, fsdp: bool, dp):
    d = dp if fsdp else None
    L = lambda *rest: P(*((None,) + rest))
    # NOTE: n_heads=4 < model-axis size, so head-structured recurrent weights
    # (r_gates) stay replicated; channel-structured projections shard.
    slstm = {
        "norm": L(None), "w_gates": L(d, "model"),
        "r_gates": L(None, None, None),
        "b_gates": L("model"), "w_out": L(d, "model"),
    }
    mlstm = {
        "norm": L(None), "w_up": L(d, "model"), "w_gate": L(d, "model"),
        "w_q": L(d, "model"), "w_k": L(d, "model"), "w_v": L(d, "model"),
        "w_if": L(d, None), "b_if": L(None), "skip_norm": L(None),
        "w_down": L("model", d),
    }
    return {"embed": P("model", d), "slstm": slstm, "mlstm": mlstm,
            "final_norm": P(None)}


def _rglru_specs(cfg: RGLRUConfig, fsdp: bool, dp):
    d = dp if fsdp else None
    L = lambda *rest: P(*((None,) + rest))

    def rec():
        return {
            "norm": L(None), "w_x": L(d, "model"), "w_gate": L(d, "model"),
            "conv": L(None, "model"), "w_ri": L(d, "model"),
            "b_ri": L("model"), "lam": L("model"), "w_out": L("model", d),
            "mlp_norm": L(None), "wi_gate": L(d, "model"),
            "wi_up": L(d, "model"), "wo_mlp": L("model", d),
        }

    def attn():
        return {
            "norm": L(None), "wq": L(d, "model"), "wk": L(d, None),
            "wv": L(d, None), "wo": L("model", d),
            "mlp_norm": L(None), "wi_gate": L(d, "model"),
            "wi_up": L(d, "model"), "wo_mlp": L("model", d),
        }

    specs = {"embed": P("model", d), "rec_a": rec(), "rec_b": rec(),
             "attn": attn(), "final_norm": P(None)}
    if cfg.n_tail_rec:
        specs["tail"] = rec()
    return specs


def param_specs(cfg, *, fsdp: bool = True, multi_pod: bool = False,
                fsdp_axis: str = "data"):
    """PartitionSpec tree matching the arch's init_params structure.

    NOTE on w_ri sharding (rglru): the RG-LRU is elementwise over channels,
    so sharding its channel dim over "model" keeps the whole recurrence
    collective-free.
    """
    from repro.models.rglru import RGLRUConfig
    from repro.models.xlstm import XLSTMConfig
    if isinstance(cfg, XLSTMConfig):
        return _xlstm_specs(cfg, fsdp, fsdp_axis)
    if isinstance(cfg, RGLRUConfig):
        return _rglru_specs(cfg, fsdp, fsdp_axis)
    return _transformer_specs(cfg, fsdp, fsdp_axis)


def batch_specs(kind: str, multi_pod: bool = False):
    b = P(batch_axes(multi_pod))
    specs = {"tokens": b, "labels": b}
    if kind == "vlm":
        specs["positions"] = P(None, batch_axes(multi_pod))
    return specs


def cache_specs(cfg, multi_pod: bool = False):
    """KV-cache/serving-state specs: batch over dp axes, kv-heads/channels
    over model where divisible."""
    from repro.models.rglru import RGLRUConfig
    from repro.models.xlstm import XLSTMConfig
    b = batch_axes(multi_pod)
    if isinstance(cfg, XLSTMConfig):
        s = P(None, b)
        return {"slstm": (s, s, s, s), "mlstm": (s, s, s), "pos": P()}
    if isinstance(cfg, RGLRUConfig):
        rec = (P(None, b, "model"), P(None, b, None, "model"))
        out = {"rec_a": rec, "rec_b": rec,
               "attn": (P(None, b), P(None, b)), "pos": P()}
        if cfg.n_tail_rec:
            out["tail"] = rec
        return out
    # transformer: (L, B, M, Hkv, hd); shard kv heads over model if divisible
    return {"k": P(None, b), "v": P(None, b), "pos": P()}


def opt_state_specs(pspecs):
    """Adam m/v shard exactly like params (ZeRO)."""
    return {"m": pspecs, "v": pspecs, "step": P()}


def train_state_specs(pspecs):
    """Spec tree matching train/loop.py's {params, opt, step} state."""
    return {"params": pspecs, "opt": opt_state_specs(pspecs), "step": P()}


def diloco_specs(pspecs, *, compress: bool = False,
                 screen: bool = False):
    """Spec tree matching train/diloco.py's diloco_init structure: global
    params/momentum shard like a single replica; the per-pod replicas carry
    an explicit leading axis sharded over "pod" (pod-local inner compute)."""
    pod = lambda t: prepend_axis(t, "pod")
    specs = {
        "global_params": pspecs,
        "outer_m": pspecs,
        "pod_params": pod(pspecs),
        "pod_opt": pod(opt_state_specs(pspecs)),
        "step": P(),
    }
    if compress:
        specs["pod_ef"] = pod(pspecs)
    if screen:
        specs["screen"] = {"loss": P("pod", None),
                           "gnorm": P("pod", None),
                           "count": P("pod")}
    return specs
